"""Co-PLMs Algorithm 1 end-to-end on a simulated cloud-edge consortium:
1 server (GPT-J-6B family, reduced) + 3 heterogeneous edge devices
(Bloom / Sheared-LLaMA / Qwen2.5 families, reduced) with heterogeneous
tokenizers and Dirichlet-skewed domain shards.

  PYTHONPATH=src python examples/cotune_cluster.py [--rounds 2] [--lam 0.1]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.core.cotuning import CoPLMs, CoTuneConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--lam", type=float, default=1.0, help="Dirichlet DDS")
    ap.add_argument("--saml-steps", type=int, default=6)
    ap.add_argument("--dst-steps", type=int, default=3)
    args = ap.parse_args()

    cfg = CoTuneConfig(
        rounds=args.rounds, dst_steps=args.dst_steps, saml_steps=args.saml_steps,
        distill_steps=20, pretrain_steps=40, batch_size=8, seq_len=48,
        samples_per_client=192, n_eval=32, lam=args.lam,
    )
    slms = [
        get_arch("paper-bloom-1.1b"),
        get_arch("paper-llama2-1.3b"),
        get_arch("paper-qwen2.5-1.5b"),
    ]
    print("building consortium (distilling DPM from the server LLM)...")
    system = CoPLMs.build(slms, get_arch("paper-gptj-6b"), get_arch("paper-dpm"), cfg)
    print("eval BEFORE co-tuning:", system.evaluate())
    for t in range(cfg.rounds):
        m = system.round(t)
        print(f"round {t}: " + ", ".join(f"{k}={v:.3f}" for k, v in m.items()))
    print("eval AFTER co-tuning:", system.evaluate())
    print("comm fraction (Fig.3 metric):", system.comm_fraction())


if __name__ == "__main__":
    main()
