"""Continuous batching over a pool of requests — the serving-side example.

  PYTHONPATH=src python examples/serve_pool.py [--arch xlstm-1.3b]

Two request waves stream through ONE persistent ServeEngine: wave 1 is
submitted while wave 0 is still decoding, and its requests are admitted
into slots as wave-0 streams finish — no wave barrier, no cache
reinitialization. xlstm/jamba archs show the O(1)-state decode (cache size
independent of generated length).
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.common.module import param_bytes
from repro.configs import get_arch
from repro.data.synthetic import generate_corpus
from repro.data.tokenizer import build_tokenizer
from repro.models.model import build_model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b")
    ap.add_argument("--batch", type=int, default=6)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    corpus = generate_corpus(100, seed=1)
    tok = build_tokenizer("pool", [s.text for s in corpus], budget=1024)
    cfg = dataclasses.replace(get_arch(args.arch).reduced(), vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b, max_len = args.batch, 96

    engine = ServeEngine(
        model, params, max_batch=b, max_len=max_len, eos_id=tok.eos_id, seed=1
    )
    geom = engine.cache.geom
    print(
        f"{cfg.name}: params {param_bytes(params) / 1e6:.1f}MB, "
        f"paged cache {engine.cache_bytes / 1e6:.2f}MB for {b} slots x "
        f"{max_len} positions ({engine.cache.num_pages} pages of "
        f"{geom.page_size})"
    )

    def submit_wave(wave: int):
        reqs = corpus[wave * b : (wave + 1) * b]
        rids = []
        for s in reqs:
            ids = tok.encode(f"question : {s.question} answer :", bos=True)
            rids.append(engine.submit(ids, max_new=args.gen))
        return set(rids)

    t0 = time.time()
    waves = [submit_wave(0)]
    done = {}
    steps = 0
    # wave 1 arrives mid-flight of wave 0 (or right as it drains, for tiny
    # --gen values where wave 0 finishes before the trigger step)
    trigger = max(1, min(4, args.gen // 2))
    wave1_submitted = False
    while engine.num_queued or engine.num_active or not wave1_submitted:
        if not wave1_submitted and (
            steps == trigger or not (engine.num_queued or engine.num_active)
        ):
            waves.append(submit_wave(1))
            wave1_submitted = True
            print(f"step {steps}: wave 1 submitted "
                  f"({engine.num_active} streams still decoding wave 0)")
        for c in engine.step():
            done[c.rid] = c
        steps += 1
    dt = time.time() - t0

    for w, rids in enumerate(waves):
        cs = [done[r] for r in sorted(rids)]
        ttft = sum(c.ttft_s for c in cs) / len(cs)
        ntok = sum(len(c.tokens) for c in cs)
        print(f"wave {w}: {len(cs)} requests, {ntok} tokens, "
              f"mean ttft {ttft * 1e3:.0f}ms")
    print(f"total {dt:.2f}s | {engine.stats.summary()}")
    print(f"prefill buckets compiled: {engine.runner.prefill_programs} | "
          f"decode lane buckets: {engine.runner.decode_programs} | "
          f"mean occupancy {engine.mean_occupancy:.2f}")


if __name__ == "__main__":
    main()
