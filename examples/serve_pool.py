"""Batched serving with a KV cache over a pool of requests — the serving-
side example (decode path = what decode_32k / long_500k dry-runs lower).

  PYTHONPATH=src python examples/serve_pool.py [--arch xlstm-1.3b]

Two request waves share the serve_step program; xlstm/jamba archs show the
O(1)-state decode (cache size independent of generated length).
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.module import param_bytes
from repro.configs import get_arch
from repro.data.synthetic import generate_corpus
from repro.data.tokenizer import build_tokenizer
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b")
    ap.add_argument("--batch", type=int, default=6)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    corpus = generate_corpus(100, seed=1)
    tok = build_tokenizer("pool", [s.text for s in corpus], budget=1024)
    cfg = dataclasses.replace(get_arch(args.arch).reduced(), vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b, max_len = args.batch, 96
    serve = jax.jit(model.serve_step)

    cache = model.init_cache(b, max_len)
    cache_b = sum(x.nbytes for x in jax.tree.leaves(cache))
    print(
        f"{cfg.name}: params {param_bytes(params) / 1e6:.1f}MB, "
        f"cache {cache_b / 1e6:.2f}MB for {b} streams x {max_len} positions"
    )

    for wave in range(2):
        reqs = corpus[wave * b : (wave + 1) * b]
        enc = [tok.encode(f"question : {s.question} answer :", bos=True) for s in reqs]
        plen = min(len(e) for e in enc)
        toks = np.stack([e[:plen] for e in enc]).astype(np.int32)
        cache = model.init_cache(b, max_len)

        def dbatch(tk, pos):
            d = {"token": jnp.asarray(tk), "pos": jnp.asarray(pos, jnp.int32)}
            if cfg.vision_embeds:
                d["mrope_pos"] = jnp.full((3, b, 1), pos, jnp.int32)
            if cfg.is_encoder_decoder:
                d["enc"] = jnp.zeros((b, max_len // 4, cfg.d_model), jnp.bfloat16)
            return d

        logits = None
        t0 = time.time()
        for i in range(plen):
            logits, cache = serve(params, cache, dbatch(toks[:, i], i))
        nxt = np.asarray(jnp.argmax(logits, -1))
        outs = []
        for j in range(args.gen):
            outs.append(nxt)
            logits, cache = serve(params, cache, dbatch(nxt, plen + j))
            nxt = np.asarray(jnp.argmax(logits, -1))
        dt = time.time() - t0
        print(
            f"wave {wave}: {b} streams, prefill {plen} + gen {args.gen} "
            f"in {dt:.2f}s ({b * args.gen / dt:.0f} gen tok/s)"
        )


if __name__ == "__main__":
    main()
