"""Quickstart: build a reduced model from the zoo, train it briefly on the
synthetic QA corpus, and generate.

  PYTHONPATH=src python examples/quickstart.py [--arch gemma-2b]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.core.evalqa import evaluate_qa, greedy_generate
from repro.data.pipeline import QADataset, make_batches
from repro.data.synthetic import generate_corpus
from repro.data.tokenizer import build_tokenizer
from repro.models.model import build_model
from repro.optim.adamw import AdamW


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    corpus = generate_corpus(200, seed=0)
    tok = build_tokenizer("qs", [s.text for s in corpus], budget=1024)
    cfg = dataclasses.replace(get_arch(args.arch).reduced(), vocab_size=tok.vocab_size)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(learning_rate=1e-3, weight_decay=0.01)
    state = opt.init(params)

    @jax.jit
    def step(p, s, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        p2, s2 = opt.update(grads, s, p)
        return p2, s2, loss

    ds = QADataset(corpus[:160], tok, seq_len=48)
    for i, batch in enumerate(make_batches(ds, 8, epochs=100)):
        if i >= args.steps:
            break
        jb = {k: jnp.asarray(v) for k, v in batch.items() if k != "sample_idx"}
        params, state, loss = step(params, state, jb)
        if i % 10 == 0:
            print(f"step {i:3d} loss {float(loss):.3f}")

    m = evaluate_qa(model, params, tok, corpus[160:180], max_new=8)
    print("eval:", m)
    outs = greedy_generate(
        model, params, tok,
        [f"question : {s.question} answer :" for s in corpus[160:163]],
        max_new=8,
    )
    for s, o in zip(corpus[160:163], outs):
        print(f"Q: {s.question}\n   pred={o!r} gold={s.answer!r}")


if __name__ == "__main__":
    main()
